"""Paper Fig. 4 sweeps: degree/theta run-time-vs-error curves, plus the
Yukawa kappa sweep as ONE vmapped ensemble launch.

The kappa sweep used to loop `solver(pts, pts, q, kernel_params=...)`
per value — five sequential launches of the same geometry. Kernel
parameters are traced (protocol v2) and the ensemble subsystem stacks
identical systems at zero padding cost, so the five kappa values now
ride a single `EnsemblePlan` launch and compile exactly once (asserted).

    PYTHONPATH=src python examples/figure4_sweep.py [--n 4000]
    PYTHONPATH=src python examples/figure4_sweep.py --kappa-only
"""
import argparse


def kappa_sweep(n_particles=2000, kappas=(0.1, 0.3, 0.5, 0.7, 1.0),
                x64=True):
    """Yukawa phi for every kappa in one batched launch; returns
    {kappa: rel-l2 distance from the coulomb (kappa->0) limit}."""
    import jax
    if x64:
        jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import eval as _eval
    from repro.core.api import TreecodeConfig
    from repro.serve import EnsemblePlan

    rng = np.random.default_rng(0)
    dtype = np.float64 if x64 else np.float32
    pts = rng.uniform(-1, 1, (n_particles, 3)).astype(dtype)
    q = rng.uniform(-1, 1, n_particles).astype(dtype)

    cfg = TreecodeConfig(kernel="yukawa", theta=0.7, degree=6,
                        leaf_size=200, backend="xla")
    plan = EnsemblePlan.build(cfg, [pts] * len(kappas))
    before = _eval.ensemble_compile_count()
    phi = plan.execute([q] * len(kappas),
                       kernel_params=[{"kappa": k} for k in kappas])
    phi.block_until_ready()
    compiles = _eval.ensemble_compile_count() - before
    assert compiles == 1, (
        f"kappa sweep must compile exactly once, compiled {compiles}x")

    phis = [np.asarray(p) for p in plan.split(phi)]
    base = phis[0]
    out = {}
    for k, p in zip(kappas, phis):
        out[k] = float(np.linalg.norm(p - base) / np.linalg.norm(base))
    return out, compiles


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--kappa-only", action="store_true",
                    help="skip the degree/theta sweep")
    args = ap.parse_args()

    if not args.kappa_only:
        from benchmarks.fig4 import check_paper_claims, run
        print("kernel,theta,degree,time_s,rel2_err,direct_time_s")
        rows = run(n_particles=args.n, degrees=(1, 2, 4, 6, 8, 10))
        print()
        for msg in check_paper_claims(rows):
            print(msg)
        print()

    screen, compiles = kappa_sweep(n_particles=min(args.n, 2000))
    print(f"kappa sweep: 1 ensemble launch, {compiles} compile")
    print("kappa,rel2_vs_smallest_kappa")
    for k, d in screen.items():
        print(f"{k},{d:.3e}")


if __name__ == "__main__":
    main()
