"""Reproduce the structure of paper Fig. 4: run time vs error for MAC
theta in {0.5, 0.7, 0.9} as the interpolation degree n sweeps up, for the
Coulomb and Yukawa kernels, against the direct-sum baseline (FP64, scaled
N for a single CPU core).

    PYTHONPATH=src python examples/figure4_sweep.py [--n 4000]
"""
import argparse

from benchmarks.fig4 import check_paper_claims, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    args = ap.parse_args()
    print("kernel,theta,degree,time_s,rel2_err,direct_time_s")
    rows = run(n_particles=args.n, degrees=(1, 2, 4, 6, 8, 10))
    print()
    for msg in check_paper_claims(rows):
        print(msg)


if __name__ == "__main__":
    main()
