"""End-to-end driver: N-body dynamics with treecode forces.

Velocity-Verlet integration of a softened Coulomb system; forces are the
exact gradient of the *treecode-approximated* potential with respect to
the target coordinates, obtained with three forward-mode JVPs through the
jitted evaluation pipeline (the BLTC is differentiable JAX code — no
finite differences, no extra kernels). The tree is rebuilt every step as
particles move, exactly like production treecode MD.

    PYTHONPATH=src python examples/md_nbody.py [--n 1500] [--steps 200]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import eval as ceval
from repro.core.api import TreecodeConfig, TreecodeSolver


def forces(solver, plan, points, charges, eps2=1e-4):
    """F_i = -q_i grad_x phi(x_i) via 3 JVPs through the evaluation."""
    arrays = dict(plan.arrays)
    cfg = solver.config

    def phi_of_tgt(tgt):
        a = dict(arrays, tgt_batched=tgt)
        return ceval.execute(a, jnp.asarray(charges), degree=cfg.degree,
                             kernel=solver._kernel, backend="xla",
                             precompute=cfg.precompute)

    tgt = arrays["tgt_batched"]
    grads = []
    for d in range(3):
        tangent = jnp.zeros_like(tgt).at[..., d].set(1.0)
        _, dphi = jax.jvp(phi_of_tgt, (tgt,), (tangent,))
        grads.append(dphi)
    g = jnp.stack(grads, axis=-1)           # (N, 3) dphi/dx_i
    return -jnp.asarray(charges)[:, None] * g


def potential_energy(phi, charges):
    return 0.5 * float(jnp.sum(jnp.asarray(charges) * phi))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dt", type=float, default=2e-4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (args.n, 3)).astype(np.float32)
    q = (rng.uniform(-1, 1, args.n) * 0.05).astype(np.float32)
    v = np.zeros_like(x)
    mass = 1.0

    solver = TreecodeSolver(TreecodeConfig(
        theta=0.8, degree=6, leaf_size=128, precompute="hierarchical"))

    t0 = time.time()
    plan = solver.plan(x, x)
    f = np.asarray(forces(solver, plan, x, q))
    for step in range(args.steps):
        v += 0.5 * args.dt * f / mass
        x += args.dt * v
        plan = solver.plan(x, x)               # rebuild tree (moving pts)
        f = np.asarray(forces(solver, plan, x, q))
        v += 0.5 * args.dt * f / mass
        if step % max(1, args.steps // 10) == 0:
            phi = solver.execute(plan, q)
            pe = potential_energy(phi, q)
            ke = 0.5 * mass * float((v * v).sum())
            print(f"step {step:4d}  KE {ke:10.6f}  PE {pe:10.6f}  "
                  f"E {ke + pe:10.6f}", flush=True)
    print(f"{args.steps} MD steps in {time.time()-t0:.1f}s "
          f"({(time.time()-t0)/args.steps*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
