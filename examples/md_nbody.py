"""End-to-end driver: N-body dynamics on the device-resident MD engine.

The `repro.dynamics.Simulation` engine replaces the rebuild-every-step
loop this example used to run by hand:

  - the jitted inner step fuses integrator half-kicks, the device tree
    refit, and the treecode force evaluation (a custom VJP through the
    jitted pipeline) — forces never visit the host between half-kicks,
    and there is no per-step `np.asarray(f)` round-trip;
  - the host tree is rebuilt only every `--refit-interval` steps (or
    earlier if particle drift exhausts the MAC slack budget), and each
    rebuild is re-padded into fixed buffer capacities so the compiled
    step executable is reused instead of retraced;
  - `--rebuild always` recovers the old naive behaviour for comparison.

Pass ``--box L`` for periodic boundary conditions (minimum-image
convention in the cell [0, L)^3: the tree builds on wrapped coordinates,
kernels fold displacements, and the engine re-wraps positions at every
rebuild) — combine with ``--kernel yukawa --kappa 0.8`` for the classic
screened molten-salt setting.

    PYTHONPATH=src python examples/md_nbody.py [--n 1500] [--steps 200]
        [--integrator velocity_verlet|leapfrog|langevin]
        [--refit-interval 25] [--rebuild auto|always|never]
        [--box 0] [--kernel coulomb] [--kappa 0.5]
        [--checkpoint DIR]
"""
import argparse
import time

import numpy as np

from repro.checkpoint.store import Checkpointer
from repro.core.api import TreecodeConfig, TreecodeSolver
from repro.core.space import FreeSpace, PeriodicBox
from repro.dynamics import Simulation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dt", type=float, default=2e-4)
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--degree", type=int, default=4)
    ap.add_argument("--leaf-size", type=int, default=64)
    ap.add_argument("--skin", type=float, default=0.0,
                    help="Verlet-skin radius: floors the refit drift "
                         "budget at skin/2 (drift-budget v2)")
    ap.add_argument("--integrator", default="velocity_verlet")
    ap.add_argument("--temperature", type=float, default=0.05,
                    help="langevin target temperature")
    ap.add_argument("--friction", type=float, default=1.0,
                    help="langevin friction")
    ap.add_argument("--refit-interval", type=int, default=25)
    ap.add_argument("--rebuild", default="auto",
                    choices=("auto", "always", "never"))
    ap.add_argument("--box", type=float, default=0.0,
                    help="periodic box edge L (0 = free space); particles "
                         "start uniform in [0, L)^3")
    ap.add_argument("--kernel", default="coulomb",
                    choices=("coulomb", "yukawa"))
    ap.add_argument("--kappa", type=float, default=0.5,
                    help="yukawa inverse screening length")
    ap.add_argument("--checkpoint", default=None,
                    help="directory for trajectory checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    if args.box > 0:
        space = PeriodicBox((args.box,) * 3)
        x = rng.uniform(0, args.box, (args.n, 3)).astype(np.float32)
    else:
        space = FreeSpace()
        x = rng.uniform(-1, 1, (args.n, 3)).astype(np.float32)
    q = (rng.uniform(-1, 1, args.n) * 0.05).astype(np.float32)

    kparams = {"kappa": args.kappa} if args.kernel == "yukawa" else {}
    solver = TreecodeSolver(TreecodeConfig(
        theta=args.theta, degree=args.degree, leaf_size=args.leaf_size,
        kernel=args.kernel, kernel_params=kparams, space=space,
        skin=args.skin))
    plan = solver.plan(x)

    params = {}
    if args.integrator == "langevin":
        params = dict(friction=args.friction, temperature=args.temperature)
    ckpt = Checkpointer(args.checkpoint) if args.checkpoint else None
    sim = Simulation(plan, q, dt=args.dt, integrator=args.integrator,
                     integrator_params=params,
                     refit_interval=args.refit_interval,
                     rebuild=args.rebuild,
                     checkpointer=ckpt,
                     checkpoint_every=args.checkpoint_every)

    record_every = max(1, args.steps // 10)
    t0 = time.time()

    def report(s):
        if s.steps % record_every:
            return
        d = s.log.last()
        print(f"step {s.steps:4d}  KE {d['kinetic']:10.6f}  "
              f"PE {d['potential']:10.6f}  E {d['energy']:10.6f}  "
              f"T {d['temperature']:8.5f}", flush=True)

    sim.run(args.steps, record_every=record_every, callback=report)
    elapsed = time.time() - t0

    s = sim.stats()
    print(f"\n{args.steps} MD steps in {elapsed:.1f}s "
          f"({elapsed / args.steps * 1e3:.0f} ms/step)")
    print(f"refits {s['refits']}  rebuilds {s['rebuilds']} "
          f"(drift {s['rebuilds_drift']}, interval {s['rebuilds_interval']})"
          f"  retraces {s['retraces']}")
    print(f"energy drift {sim.log.drift():.2e}  "
          f"momentum drift {sim.log.momentum_drift():.2e}")
    if ckpt is not None:
        ckpt.wait()
        print(f"checkpoints under {args.checkpoint}")


if __name__ == "__main__":
    main()
