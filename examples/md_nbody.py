"""End-to-end driver: N-body dynamics with treecode forces.

Velocity-Verlet integration of a softened Coulomb system using the
first-class force entry point: `plan.potential_and_forces(q)` returns the
potentials and F_i = -q_i grad phi(x_i), where the gradient is the exact
derivative of the *treecode-approximated* potential (a custom VJP backed
by three forward-mode JVPs through the jitted pipeline — no finite
differences, no extra kernels). The tree is rebuilt every step via
`plan.replan` as particles move, exactly like production treecode MD.

    PYTHONPATH=src python examples/md_nbody.py [--n 1500] [--steps 200]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core.api import TreecodeConfig, TreecodeSolver


def potential_energy(phi, charges):
    return 0.5 * float(jnp.sum(jnp.asarray(charges) * phi))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dt", type=float, default=2e-4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (args.n, 3)).astype(np.float32)
    q = (rng.uniform(-1, 1, args.n) * 0.05).astype(np.float32)
    v = np.zeros_like(x)
    mass = 1.0

    solver = TreecodeSolver(TreecodeConfig(
        theta=0.8, degree=6, leaf_size=128, precompute="hierarchical"))

    t0 = time.time()
    plan = solver.plan(x, nranks=1)
    phi, f = plan.potential_and_forces(q)
    f = np.asarray(f)
    for step in range(args.steps):
        v += 0.5 * args.dt * f / mass
        x += args.dt * v
        plan = plan.replan(x)                  # rebuild tree (moving pts)
        phi, f = plan.potential_and_forces(q)
        f = np.asarray(f)
        v += 0.5 * args.dt * f / mass
        if step % max(1, args.steps // 10) == 0:
            pe = potential_energy(phi, q)
            ke = 0.5 * mass * float((v * v).sum())
            print(f"step {step:4d}  KE {ke:10.6f}  PE {pe:10.6f}  "
                  f"E {ke + pe:10.6f}", flush=True)
    print(f"{args.steps} MD steps in {time.time()-t0:.1f}s "
          f"({(time.time()-t0)/args.steps*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
