"""Quickstart: the unified plan/execute/forces API on 20k Coulomb particles.

One solver facade covers every execution strategy:

  plan = solver.plan(points)             # SingleDevicePlan or ShardedPlan
  phi  = plan.execute(charges)           # potentials, input order
  phi, F = plan.potential_and_forces(q)  # + forces F_i = -q_i grad phi_i
  plan = plan.replan(new_points)         # moving particles (MD)

Run on N devices (e.g. a forced-host-device CPU check) and `solver.plan`
auto-shards via RCB + locally essential trees:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/quickstart.py

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.api import TreecodeConfig, TreecodeSolver
from repro.core.direct import direct_sum


def main():
    rng = np.random.default_rng(0)
    n = 20_000
    # random particles in the [-1,1]^3 cube, charges uniform on [-1,1]
    # (the paper's Sec. 4 test setting)
    points = rng.uniform(-1, 1, (n, 3)).astype(np.float32)
    charges = rng.uniform(-1, 1, n).astype(np.float32)

    solver = TreecodeSolver(TreecodeConfig(
        theta=0.8, degree=8, leaf_size=512, kernel="coulomb"))

    t0 = time.time()
    plan = solver.plan(points)            # sources default to the targets
    phi = plan.execute(charges)
    phi.block_until_ready()
    t_tree = time.time() - t0
    stats = plan.stats()

    t0 = time.time()
    phi_ds = direct_sum(jnp.asarray(points), jnp.asarray(points),
                        jnp.asarray(charges), kernel=solver.kernel)
    phi_ds.block_until_ready()
    t_direct = time.time() - t0

    err = float(jnp.linalg.norm(phi - phi_ds) / jnp.linalg.norm(phi_ds))
    print(f"N = {n}   strategy = {stats['strategy']} "
          f"(nranks = {stats['nranks']})")
    print(f"treecode: {t_tree:.2f}s (incl. tree build)   "
          f"direct sum: {t_direct:.2f}s")
    print(f"relative 2-norm error (paper Eq. 16): {err:.2e}")
    print(f"interaction-list padding waste: {stats['padding_waste']:.1%}")

    # plan reuse with new charges (boundary-element / iterative-solver use;
    # set donate_charges=True to recycle the device buffer in such loops)
    charges2 = rng.uniform(-1, 1, n).astype(np.float32)
    t0 = time.time()
    plan.execute(charges2).block_until_ready()
    print(f"re-execute with new charges: {time.time() - t0:.2f}s")

    # forces through the same plan (differentiable entry point)
    t0 = time.time()
    _, forces = plan.potential_and_forces(charges)
    jnp.asarray(forces).block_until_ready()
    print(f"potential + forces: {time.time() - t0:.2f}s  "
          f"|F| max = {float(jnp.abs(forces).max()):.3g}")


if __name__ == "__main__":
    main()
