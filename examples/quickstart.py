"""Quickstart: fast summation of 20k Coulomb particles with the BLTC.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.api import TreecodeConfig, TreecodeSolver
from repro.core.direct import direct_sum


def main():
    rng = np.random.default_rng(0)
    n = 20_000
    # random particles in the [-1,1]^3 cube, charges uniform on [-1,1]
    # (the paper's Sec. 4 test setting)
    points = rng.uniform(-1, 1, (n, 3)).astype(np.float32)
    charges = rng.uniform(-1, 1, n).astype(np.float32)

    solver = TreecodeSolver(TreecodeConfig(
        theta=0.8, degree=8, leaf_size=512, kernel="coulomb",
        precompute="hierarchical"))

    t0 = time.time()
    plan = solver.plan(points, points)
    phi = solver.execute(plan, charges)
    phi.block_until_ready()
    t_tree = time.time() - t0

    t0 = time.time()
    phi_ds = direct_sum(jnp.asarray(points), jnp.asarray(points),
                        jnp.asarray(charges),
                        kernel=solver.config.make_kernel())
    phi_ds.block_until_ready()
    t_direct = time.time() - t0

    err = float(jnp.linalg.norm(phi - phi_ds) / jnp.linalg.norm(phi_ds))
    print(f"N = {n}")
    print(f"treecode: {t_tree:.2f}s (incl. tree build)   "
          f"direct sum: {t_direct:.2f}s")
    print(f"relative 2-norm error (paper Eq. 16): {err:.2e}")
    print(f"interaction-list padding waste: {plan.padding_waste:.1%}")

    # plan reuse with new charges (boundary-element / iterative-solver use)
    charges2 = rng.uniform(-1, 1, n).astype(np.float32)
    t0 = time.time()
    solver.execute(plan, charges2).block_until_ready()
    print(f"re-execute with new charges: {time.time() - t0:.2f}s")


if __name__ == "__main__":
    main()
